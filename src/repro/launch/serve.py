"""Serving driver: continuous batching over the paged-KV engine.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --requests 16

Scheduler knobs (DESIGN.md §8): ``--pin-pages`` keeps hot prompt
prefixes cache-pinned across request lifetimes, ``--page-budget``
tightens per-shard admission (forcing deferral/preemption under load),
``--interactive-frac`` tags a fraction of requests into the
higher-priority SLO class.

Token-lane knobs (DESIGN.md §10): ``--chunk-buckets`` hands the
scheduler a static set of prefill lane widths to shrink into when
latency-class work waits; ``--speculate``/``--draft-len`` turn on
speculative decode on shared prefixes (``--repeat-frac`` makes part of
the trace repeat full prompts — the traffic shape speculation wins on).

Fault knobs (DESIGN.md §11): ``--inject-fault kind@step:phase[:extra]``
deterministically injects host crashes / shard loss / stragglers /
poisoned requests at engine phase boundaries (serving/chaos.py); the
driver recovers crashes by rebuilding the engine and reconciling
allocator state from the device arrays + admission journal, then
asserts the run drained with zero leaked pages on surviving shards.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from .. import models
from ..configs import get_config, smoke_config
from ..serving import chaos
from ..serving.engine import Request, ServingEngine
from ..serving.sched import SchedConfig
from ..serving.telemetry import FlightRecorder, install_signal_dump
from ..serving.trace import Tracer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--b-local", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--pin-pages", type=int, default=0,
                    help="pinned prefix-cache pages per shard (0 = off)")
    ap.add_argument("--page-budget", type=int, default=0,
                    help="admissible worst-case pages per shard "
                         "(0 = pool capacity)")
    ap.add_argument("--interactive-frac", type=float, default=0.0,
                    help="fraction of requests in the interactive class")
    ap.add_argument("--speculate", action="store_true",
                    help="speculative decode on shared prefixes "
                         "(DESIGN.md §10)")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="draft tokens per speculative lane")
    ap.add_argument("--no-spec-gate", action="store_true",
                    help="disable the per-prefix accept-rate break-even "
                         "gate (DESIGN.md §12): always draft at full "
                         "draft-len")
    ap.add_argument("--chunk-buckets", default="",
                    help="comma-separated SLO-aware prefill lane widths "
                         "(e.g. 1,4,8); empty = fixed chunk")
    ap.add_argument("--hot-prefix", type=int, default=0, metavar="N",
                    help="prepend a common N-token prefix to every prompt")
    ap.add_argument("--repeat-frac", type=float, default=0.0,
                    help="fraction of requests repeating a previous full "
                         "prompt (the speculative fast path)")
    ap.add_argument("--size-classes", type=int, default=1,
                    choices=(1, 2, 3),
                    help="allocation-plane size classes (DESIGN.md "
                         "§14): 1 = single coarse KV class (the "
                         "pre-classed plane, bit-identical), 2 = add "
                         "the fine bounded-state class, 3 = add the "
                         "read-only expert-weight class (§15)")
    ap.add_argument("--expert-paging", action="store_true",
                    help="page MoE expert weights through the classed "
                         "pool (CLS_EXPERT; DESIGN.md §15) — implies "
                         "size-classes >= 3; no-op for dense models")
    ap.add_argument("--expert-budget", type=int, default=0,
                    help="resident expert pages per shard (0 = full "
                         "residency; 3 pages per expert per MoE layer "
                         "slot)")
    ap.add_argument("--expert-frac", type=float, default=0.0,
                    help="fraction of requests restricted to a random "
                         "half of the experts (footprint skew the "
                         "load-aware admission learns)")
    ap.add_argument("--mesh", choices=("auto", "off"), default="auto",
                    help="shard_map the allocation plane over a ('dp',) "
                         "device mesh when >= dp devices exist "
                         "(DESIGN.md §9); off = single-device vmap")
    ap.add_argument("--inject-fault", default="", metavar="SPEC",
                    help="deterministic fault schedule, comma-joined "
                         "kind@step:phase[:extra] (serving/chaos.py)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request deadline in seconds (0 = none)")
    ap.add_argument("--metrics-path", default="", metavar="FILE",
                    help="write a Prometheus text-format telemetry "
                         "snapshot here at end of run (DESIGN.md §13)")
    ap.add_argument("--trace-path", default="", metavar="FILE",
                    help="write the request-lifecycle trace here at end "
                         "of run (chrome trace_event JSON; a .jsonl "
                         "suffix writes one event per line instead)")
    ap.add_argument("--flight-recorder", default="", metavar="FILE",
                    help="crash flight-recorder dump path: the last-N-"
                         "steps ring dumps here on crash, watchdog "
                         "timeout, reconcile, or SIGTERM")
    ap.add_argument("--flight-sync", type=int, default=0, metavar="N",
                    help="also dump the flight ring every N steps "
                         "(covers SIGKILL; 0 = only on crash paths)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    buckets = tuple(int(b) for b in args.chunk_buckets.split(",") if b)
    faults = bool(args.inject_fault)
    journal = chaos.ServingJournal() if faults else None
    injector = chaos.parse_faults(args.inject_fault) if faults else None

    tracer = Tracer() if args.trace_path else None

    def build():
        # a fresh recorder per build: chaos.recover_engine adopts the
        # crashed ring into it, so the forensic window spans the crash
        flight = (FlightRecorder(path=args.flight_recorder,
                                 sync_every=args.flight_sync)
                  if args.flight_recorder or args.flight_sync else None)
        eng = ServingEngine(
            cfg, params, dp=args.dp, b_local=args.b_local,
            max_len=args.max_len,
            speculate=args.speculate, draft_len=args.draft_len,
            spec_gate=not args.no_spec_gate,
            mesh=("auto" if args.mesh == "auto" else None),
            size_classes=args.size_classes,
            expert_paging=args.expert_paging,
            expert_budget=(args.expert_budget or None),
            sched=SchedConfig(pin_pages=args.pin_pages,
                              page_budget=args.page_budget,
                              chunk_buckets=buckets),
            journal=journal, injector=injector, max_restarts=4,
            tracer=tracer, flight=flight)
        if args.flight_recorder:
            install_signal_dump(eng.flight)
        return eng

    engine = build()
    if engine.mesh is not None:
        print(f"allocation plane: shard_map over {engine.mesh} "
              f"({engine.dp} shard-owning devices)")
    else:
        print(f"allocation plane: single-device vmap "
              f"({len(jax.devices())} device(s) for dp={engine.dp})")
    rng = np.random.RandomState(0)
    hot = list(rng.randint(1, cfg.vocab - 1, args.hot_prefix))
    prompts = []
    for rid in range(args.requests):
        slo = ("interactive" if rng.random_sample() < args.interactive_frac
               else "standard")
        if prompts and rng.random_sample() < args.repeat_frac:
            prompt = list(prompts[rng.randint(len(prompts))])
        else:
            prompt = hot + list(rng.randint(1, cfg.vocab - 1,
                                            rng.randint(4, 12)))
        prompts.append(prompt)
        experts = None
        if (cfg.moe is not None and args.expert_frac > 0
                and rng.random_sample() < args.expert_frac):
            E = cfg.moe.num_experts
            k = max(cfg.moe.top_k, E // 2)
            experts = tuple(
                int(e) for e in rng.choice(E, size=k, replace=False))
        engine.submit(Request(rid, prompt=prompt,
                              max_new_tokens=args.max_new, slo=slo,
                              deadline_s=args.deadline_s,
                              experts=experts))
    t0 = time.time()
    crashes = 0
    while True:
        try:
            engine.run()
            break
        except chaos.HostCrash:
            crashes += 1
            engine, report = chaos.recover_engine(build, engine, journal)
            print(f"[chaos] host crash #{crashes} at step "
                  f"{injector.step}: reconciled {report['reclaimed']} "
                  f"leaked pages, requeued {report['requeued']} "
                  f"requests, restored {report['pins_restored']} pins "
                  f"(never_dry={report['never_dry']})")
    dt = time.time() - t0
    s = engine.stats
    lat = engine.latency_quantiles()
    print(f"served {s['admitted']} requests, {s['tokens_out']} tokens in "
          f"{s['steps']} engine steps ({dt:.1f}s, "
          f"{s['tokens_out']/max(dt,1e-9):.1f} tok/s)")
    print(f"latency p50={lat['p50_s']*1e3:.0f}ms p99={lat['p99_s']*1e3:.0f}ms "
          f"(first token p50={lat['first_token_p50_s']*1e3:.0f}ms)")
    print(f"host allocator worst-case op steps: {s['alloc_steps_max']} "
          f"(O(1) — paper Result 1)")
    ss = engine.scheduler.stats
    print(f"scheduler: preemptions={s['preemptions']} "
          f"deferred={ss['deferred']} rejected={ss['rejected']} "
          f"pins created={s['pins_created']} "
          f"hits={s['pin_hit_reqs']} evicted={ss['pins_evicted']}")
    print(f"lane widths: {s['chunk_hist']} "
          f"(buckets={engine.scheduler.buckets(engine.chunk)})")
    if engine.speculate:
        rate = s["spec_accepted"] / max(s["spec_drafted"], 1)
        print(f"speculative: drafted={s['spec_drafted']} "
              f"accepted={s['spec_accepted']} (rate={rate:.2f}) "
              f"pages_rolled_back={s['spec_pages_rolled_back']} "
              f"accept_hist={s['accept_hist']} "
              f"gate_skips={s['spec_gate_skips']} "
              f"mixed_steps={s['spec_mixed_steps']}")
    occ = engine.shard_occupancy()
    print(f"shard occupancy: mean={occ['pages_mean_shard']} "
          f"peak={occ['pages_peak_shard']} pages per shard")
    if engine.expert_paging:
        hr = engine.telemetry.expert_hit_rate()
        dropped = int(engine.telemetry.shard["moe_dropped_tokens"].sum())
        print(f"expert paging: budget={engine.expert_budget} pages/shard "
              f"hit_rate={'n/a' if hr is None else f'{hr:.2f}'} "
              f"loads={s['expert_load_pages']} "
              f"evictions={s['expert_evictions']} "
              f"resident_peak={s['expert_pages_resident_peak']} "
              f"dropped_tokens={dropped}")
    engine.flush_pins()
    engine.flush_experts()
    if faults:
        print(f"[chaos] fired={injector.log} crashes={crashes} "
              f"shards_lost={sorted(engine.lost_shards)} "
              f"retries={s['retries']} failed={s['failed']} "
              f"deadline_expired={s['deadline_expired']}")
        assert not injector.pending(), (
            f"faults never reached: {injector.pending()}")
        assert engine.leak_free(), "pages leaked on surviving shards"
        assert not journal.in_flight(), (
            "requests neither finished nor failed")
        print(f"[chaos] drained clean: {len(journal.finished())} "
              f"finished, zero leaked pages on surviving shards")
    else:
        print(f"page occupancy after drain+flush: "
              f"{engine.page_occupancy():.4f}")
    m = engine.telemetry.never_dry_margin_min()
    print(f"never-dry margin (min over shards x steps): {m} "
          f"(>= 0 proves §4.2 held with slack)")
    if args.metrics_path:
        with open(args.metrics_path, "w") as fh:
            fh.write(engine.telemetry.render_prom())
        print(f"telemetry: prometheus snapshot -> {args.metrics_path}")
    if args.trace_path:
        if args.trace_path.endswith(".jsonl"):
            engine.tracer.write_jsonl(args.trace_path)
        else:
            engine.tracer.write_chrome(args.trace_path)
        print(f"telemetry: {len(engine.tracer.events)} trace events -> "
              f"{args.trace_path}")
    return engine


if __name__ == "__main__":
    main()
