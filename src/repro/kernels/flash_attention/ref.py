"""Pure-jnp oracle: dense causal attention (small shapes only)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, causal: bool = True):
    """q,k,v: [B, H, S, hd] -> [B, H, S, hd]."""
    B, H, S, hd = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
