"""Pallas TPU flash attention (prefill path).

grid = (B, H, nq, nk) with the KV axis innermost and sequential; the
online-softmax state lives in VMEM scratch across KV steps.  Causal
block skipping: KV blocks strictly above the diagonal are predicated out
with ``pl.when`` (no MXU work; Mosaic also elides the dead DMA on TPU
when the block index map is monotonic).  Q/K/V tiles are
[block_q, head_dim] / [block_k, head_dim] — head_dim is a lane multiple
(128) for every assigned config.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, block_q: int, block_k: int, scale: float, causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)             # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)             # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def flash_attention(q, k, v, causal: bool = True,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False):
    """q,k,v: [B, H, S, hd] -> [B, H, S, hd]."""
    B, H, S, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / (hd ** 0.5)
    grid = (B, H, nq, nk)

    return pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          scale=scale, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
