"""Jit'd wrapper for flash attention (TPU kernel / jnp ref dispatch)."""

from __future__ import annotations

import functools

import jax

from .kernel import flash_attention as _kernel
from .ref import flash_attention_ref as _ref


@functools.partial(jax.jit, static_argnames=("causal", "force"))
def flash_attention(q, k, v, causal: bool = True, force: str = "auto"):
    if force == "kernel" or (force == "auto"
                             and jax.default_backend() == "tpu"):
        return _kernel(q, k, v, causal=causal)
    if force == "interpret":
        return _kernel(q, k, v, causal=causal, interpret=True)
    return _ref(q, k, v, causal=causal)
