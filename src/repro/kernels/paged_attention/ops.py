"""Jit'd public wrapper for paged decode attention.

Selects the Pallas kernel on TPU and the pure-jnp reference elsewhere
(including the CPU dry-run); both share the exact semantics, which the
kernel test suite asserts over shape/dtype sweeps in interpret mode.
"""

from __future__ import annotations

import functools

import jax

from .kernel import paged_attention as _kernel
from .kernel import paged_attention_chunk as _chunk_kernel
from .ref import paged_attention_chunk_ref as _chunk_ref
from .ref import paged_attention_ref as _ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("force",))
def paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                    force: str = "auto"):
    """Dispatch: force in {"auto", "kernel", "interpret", "ref"}."""
    if force == "kernel" or (force == "auto" and _on_tpu()):
        return _kernel(q, k_pages, v_pages, page_table, seq_lens)
    if force == "interpret":
        return _kernel(q, k_pages, v_pages, page_table, seq_lens,
                       interpret=True)
    return _ref(q, k_pages, v_pages, page_table, seq_lens)


@functools.partial(jax.jit, static_argnames=("force",))
def paged_attention_chunk(q, k_pages, v_pages, page_table, base_lens,
                          force: str = "auto"):
    """Chunked-prefill variant; same dispatch contract as above.

    q: [B, T, H, hd]; base_lens: sequence lengths before the chunk.
    """
    if force == "kernel" or (force == "auto" and _on_tpu()):
        return _chunk_kernel(q, k_pages, v_pages, page_table, base_lens)
    if force == "interpret":
        return _chunk_kernel(q, k_pages, v_pages, page_table, base_lens,
                             interpret=True)
    return _chunk_ref(q, k_pages, v_pages, page_table, base_lens)
