"""Pure-jnp oracle for paged decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_chunk_ref(q, k_pages, v_pages, page_table, base_lens):
    """Chunked-prefill oracle.  q: [B, T, H, hd]; base_lens: int32[B].

    Query token t of sequence b sits at absolute position base_lens[b] +
    t (its K/V — and those of every earlier chunk token — are already in
    the pages); it attends causally to kv positions <= base_lens[b] + t.
    Rows past a sequence's live chunk length return zeros (all-masked
    softmax is guarded), so callers can ragged-mask afterwards.
    """
    B, T, H, hd = q.shape
    P, psz, KH, _ = k_pages.shape
    maxp = page_table.shape[1]
    L = maxp * psz
    safe = jnp.maximum(page_table, 0)
    k = k_pages[safe].reshape(B, L, KH, hd)
    v = v_pages[safe].reshape(B, L, KH, hd)
    if KH != H:
        k = jnp.repeat(k, H // KH, axis=2)
        v = jnp.repeat(v, H // KH, axis=2)
    kvpos = jnp.arange(L)
    qpos = base_lens[:, None] + jnp.arange(T)[None, :]          # [B, T]
    resident = jnp.repeat(page_table >= 0, psz, axis=1)         # [B, L]
    valid = (kvpos[None, None, :] <= qpos[:, :, None]) & resident[:, None, :]
    s = jnp.einsum("bthd,bkhd->bhtk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(valid[:, None], axis=-1, keepdims=True), p, 0.0)
    o = jnp.einsum("bhtk,bkhd->bthd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens):
    """q: [B, H, hd]; pages: [P, psz, KH, hd]; table: [B, maxp]; lens: [B].

    GQA: H q-heads read from KH kv-heads (H % KH == 0).
    """
    B, H, hd = q.shape
    P, psz, KH, _ = k_pages.shape
    maxp = page_table.shape[1]
    L = maxp * psz
    safe = jnp.maximum(page_table, 0)
    k = k_pages[safe].reshape(B, L, KH, hd)
    v = v_pages[safe].reshape(B, L, KH, hd)
    if KH != H:
        k = jnp.repeat(k, H // KH, axis=2)
        v = jnp.repeat(v, H // KH, axis=2)
    pos = jnp.arange(L)
    valid = (pos[None] < seq_lens[:, None]) & jnp.repeat(
        page_table >= 0, psz, axis=1)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)
