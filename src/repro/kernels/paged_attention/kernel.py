"""Pallas TPU paged decode-attention kernel.

Design (vLLM PagedAttention re-tiled for TPU; see DESIGN.md §2b):

* grid = (B, KH, max_pages); the page axis is innermost and sequential,
  so the online-softmax accumulator lives in VMEM scratch across pages.
* The block table is **scalar-prefetched** (pltpu.PrefetchScalarGridSpec)
  and drives the K/V page BlockSpec index_maps: page i of sequence b is
  DMA'd from HBM page ``table[b, i]`` — the block-table indirection of
  the paper's allocator, performed by the memory system, not by gathers.
* K/V page tiles are [psz, hd] with hd padded to 128 lanes by config;
  all q-heads of one kv-head group (GQA) are processed together as a
  [G, hd] tile (G = H // KH), so the MXU sees [G, hd] x [hd, psz].
* Out-of-range pages (table[b, i] < 0) are skipped by masking; dead DMA
  is avoided by clamping the index to 0 (a resident page) — the mask
  removes its contribution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(table_ref,            # scalar-prefetch: [B, maxp]
            q_ref,                # [1, G, hd]   (block for (b, kh))
            k_ref,                # [1, psz, hd] page tile
            v_ref,                # [1, psz, hd]
            lens_ref,             # [B] in SMEM-ish (small VMEM block)
            o_ref,                # [1, G, hd]
            m_scr, l_scr, acc_scr,  # VMEM scratch [G,1],[G,1],[G,hd]
            *, psz: int, scale: float):
    b = pl.program_id(0)
    i = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    page_id = table_ref[b, i]
    seq_len = lens_ref[b]
    base = i * psz

    q = q_ref[0, 0].astype(jnp.float32)                # [G, hd]
    k = k_ref[0].astype(jnp.float32)                   # [psz, hd]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # [G, psz]
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, psz), 1)
    valid = (pos < seq_len) & (page_id >= 0)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                             # [G, psz]
    corr = jnp.exp(m_prev - m_new)                     # [G, 1]
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # [G, hd]
    m_scr[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def _chunk_kernel(table_ref,          # scalar-prefetch: [B, maxp]
                  q_ref,              # [1, 1, T*G, hd] (block for (b, kh))
                  k_ref,              # [1, psz, hd] page tile
                  v_ref,              # [1, psz, hd]
                  lens_ref,           # [B] chunk-base lengths
                  o_ref,              # [1, 1, T*G, hd]
                  m_scr, l_scr, acc_scr,  # VMEM scratch [R,1],[R,1],[R,hd]
                  *, psz: int, scale: float, G: int):
    b = pl.program_id(0)
    i = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    page_id = table_ref[b, i]
    base = lens_ref[b]

    R = q_ref.shape[2]                                 # T * G rows
    q = q_ref[0, 0].astype(jnp.float32)                # [R, hd]
    k = k_ref[0].astype(jnp.float32)                   # [psz, hd]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # [R, psz]
    # row r = t * G + g is query token t; it may see kv pos <= base + t
    qpos = base + jax.lax.broadcasted_iota(jnp.int32, (R, psz), 0) // G
    kvpos = i * psz + jax.lax.broadcasted_iota(jnp.int32, (R, psz), 1)
    valid = (kvpos <= qpos) & (page_id >= 0)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                # [R, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                             # [R, psz]
    corr = jnp.exp(m_prev - m_new)                     # [R, 1]
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # [R, hd]
    m_scr[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finish():
        # rows that never saw a valid key (idle slot: page table all -1,
        # or a ragged tail past the live chunk) keep m == NEG_INF; they
        # must output zeros like the ref, not a mean of masked V (the
        # masked scores are a *finite* -1e30, so p = exp(s - m) = 1)
        seen = m_scr[...] > NEG_INF * 0.5
        o_ref[0, 0] = jnp.where(
            seen, acc_scr[...] / jnp.maximum(l_scr[...], 1e-20),
            0.0).astype(o_ref.dtype)


def paged_attention_chunk(q, k_pages, v_pages, page_table, base_lens,
                          interpret: bool = False):
    """Chunked-prefill paged attention.

    q: [B, T, H, hd] — T new tokens per sequence, causally masked within
    the chunk; k/v_pages: [P, psz, KH, hd] (the chunk's K/V already
    appended); table: [B, maxp]; base_lens: int32[B] sequence lengths
    BEFORE the chunk.  Same scalar-prefetched block-table indirection as
    the decode kernel; the q tile packs all chunk tokens of one GQA
    group as [T*G, hd] rows so the MXU sees [T*G, hd] x [hd, psz].
    """
    B, T, H, hd = q.shape
    P, psz, KH, _ = k_pages.shape
    maxp = page_table.shape[1]
    G = H // KH
    scale = 1.0 / (hd ** 0.5)

    # [B, T, KH, G, hd] -> [B, KH, T*G, hd]: row r = t * G + g
    qg = q.reshape(B, T, KH, G, hd).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(B, KH, T * G, hd)
    kp = k_pages.transpose(0, 2, 1, 3).reshape(P * KH, psz, hd)
    vp = v_pages.transpose(0, 2, 1, 3).reshape(P * KH, psz, hd)

    grid = (B, KH, maxp)

    def q_map(b, h, i, tbl):
        return (b, h, 0, 0)

    def kv_map(b, h, i, tbl):
        return (jnp.maximum(tbl[b, i], 0) * KH + h, 0, 0)

    def lens_map(b, h, i, tbl):
        return (0,)

    out = pl.pallas_call(
        functools.partial(_chunk_kernel, psz=psz, scale=scale, G=G),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, T * G, hd), q_map),
                pl.BlockSpec((1, psz, hd), kv_map),
                pl.BlockSpec((1, psz, hd), kv_map),
                pl.BlockSpec((B,), lens_map),
            ],
            out_specs=pl.BlockSpec((1, 1, T * G, hd), q_map),
            scratch_shapes=[
                pltpu.VMEM((T * G, 1), jnp.float32),
                pltpu.VMEM((T * G, 1), jnp.float32),
                pltpu.VMEM((T * G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KH, T * G, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), qg, kp, vp,
      base_lens.astype(jnp.int32))
    out = out.reshape(B, KH, T, G, hd).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, T, H, hd)


def paged_attention(q, k_pages, v_pages, page_table, seq_lens,
                    interpret: bool = False):
    """q: [B, H, hd]; k/v_pages: [P, psz, KH, hd]; table: [B, maxp]."""
    B, H, hd = q.shape
    P, psz, KH, _ = k_pages.shape
    maxp = page_table.shape[1]
    G = H // KH
    scale = 1.0 / (hd ** 0.5)

    # layout: group q by kv head -> [B, KH, G, hd]; pages to [P*? ] tiles
    qg = q.reshape(B, KH, G, hd)
    kp = k_pages.transpose(0, 2, 1, 3).reshape(P * KH, psz, hd)
    vp = v_pages.transpose(0, 2, 1, 3).reshape(P * KH, psz, hd)

    grid = (B, KH, maxp)

    def q_map(b, h, i, tbl):
        return (b, h, 0, 0)

    def kv_map(b, h, i, tbl):
        # clamp dead table entries to page 0 (resident); the in-kernel
        # mask (page_id < 0) zeroes their contribution
        return (jnp.maximum(tbl[b, i], 0) * KH + h, 0, 0)

    def lens_map(b, h, i, tbl):
        return (0,)

    def o_map(b, h, i, tbl):
        return (b, h, 0, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, psz=psz, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd), q_map),
                pl.BlockSpec((1, psz, hd), kv_map),
                pl.BlockSpec((1, psz, hd), kv_map),
                pl.BlockSpec((B,), lens_map),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd), o_map),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), qg, kp, vp,
      seq_lens.astype(jnp.int32))
    return out.reshape(B, H, hd)
