from .ops import verify_attention  # noqa: F401
from .kernel import build_verify_schedule  # noqa: F401
from .ref import verify_attention_ref  # noqa: F401
