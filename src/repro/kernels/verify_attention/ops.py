"""Jit'd public wrapper for speculative verify attention.

Same dispatch contract as kernels.paged_attention.ops: the Pallas
page-grouped kernel on TPU, the pure-jnp reference elsewhere, and
force={"kernel","interpret","ref"} for tests.
"""

from __future__ import annotations

import functools

import jax

from .kernel import verify_attention as _kernel
from .ref import verify_attention_ref as _ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("force",))
def verify_attention(q, k_pages, v_pages, page_table, base_lens,
                     force: str = "auto"):
    """Dispatch: force in {"auto", "kernel", "interpret", "ref"}."""
    if force == "kernel" or (force == "auto" and _on_tpu()):
        return _kernel(q, k_pages, v_pages, page_table, base_lens)
    if force == "interpret":
        return _kernel(q, k_pages, v_pages, page_table, base_lens,
                       interpret=True)
    return _ref(q, k_pages, v_pages, page_table, base_lens)
