"""Pallas TPU verify-attention kernel: page-grouped block schedule.

The speculative verify step is the allocator-friendly shape the paper's
refcounted pool produces: many short draft lanes (1 committed + k draft
queries each) whose block tables point at the *same* physical prefix
pages — sharing that the int16 refcounts already made explicit when
`share_prefix_step` addref'd them.  The per-lane schedule of
`paged_attention_chunk` (grid (B, KH, maxp)) re-DMAs such a hot page
once per lane reading it; this kernel inverts the schedule so each hot
page crosses HBM once per adjacency group:

* Work items.  Host/jit side builds a flat list of (page, lane, slot)
  triples — one per resident in-causal-window block-table entry — and
  sorts it by physical page id (`build_verify_schedule`).  Lanes whose
  tables share a page therefore become *consecutive* grid steps.
* Grid = (KH, n_items) with the item axis innermost and sequential.
  The K/V BlockSpec index_map is driven by the scalar-prefetched sorted
  page ids, so consecutive items on the same page map to the same block
  index and Pallas's pipeline skips the re-DMA: one HBM read per hot
  page per kv-head, regardless of how many lanes share it.
* All lanes' queries stay VMEM-resident as one [B*T*G, hd] tile (the
  verify window is tiny: T = k+1 draft positions), with one online-
  softmax accumulator row per (lane, token, q-head).  Each item scores
  the page against every row and masks to its own lane; rows of other
  lanes see NEG_INF, which the running max either ignores (m already
  finite -> p underflows to 0) or later cancels (corr = exp(-inf) = 0
  on the first real key), the same self-correcting trick the chunk
  kernel uses for dead pages.
* Dead items (non-resident or fully beyond the causal window) sort to
  the tail with their page clamped to 0: they coalesce into one masked
  DMA instead of scattering reads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def build_verify_schedule(page_table, base_lens, T: int, psz: int):
    """Sort the step's (page, lane, slot) work items by physical page.

    page_table: int32[B, maxp] (entries < 0 are dead); base_lens:
    int32[B] lane lengths before the verify window; T: verify width
    (k+1); psz: page size.  Returns (pages, lanes, slots), each
    int32[B * maxp], sorted ascending by page id with dead/out-of-window
    items (page == -1) at the tail.  Shared pages — the ones the
    refcounts count > 1 readers for — land adjacent, which is the whole
    scheduling trick.  The sort is stable, so equal pages keep lane
    order and the schedule is deterministic.
    """
    B, maxp = page_table.shape
    flat = page_table.reshape(-1).astype(jnp.int32)
    idx = jnp.arange(B * maxp, dtype=jnp.int32)
    lanes = idx // maxp
    slots = idx % maxp
    # a page whose first kv position is past the lane's last query
    # position (base + T - 1) contributes nothing
    needed = (flat >= 0) & (slots * psz <= base_lens[lanes] + T - 1)
    key = jnp.where(needed, flat, jnp.int32(2 ** 30))
    order = jnp.argsort(key)
    return (jnp.where(needed, flat, -1)[order],
            lanes[order], slots[order])


def _verify_kernel(pages_ref, lanes_ref, slots_ref,  # scalar-prefetch [NI]
                   q_ref,              # [B, 1, T*G, hd] (block for kh h)
                   k_ref,              # [1, psz, hd] page tile
                   v_ref,              # [1, psz, hd]
                   lens_ref,           # [B] verify-base lengths
                   o_ref,              # [B, 1, T*G, hd]
                   m_scr, l_scr, acc_scr,  # VMEM [B*T*G,1],[.,1],[.,hd]
                   *, psz: int, scale: float, G: int, TG: int):
    j = pl.program_id(1)
    n_items = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    page = pages_ref[j]
    lane = lanes_ref[j]
    slot = slots_ref[j]

    B = q_ref.shape[0]
    R = B * TG
    q = q_ref[:, 0].astype(jnp.float32).reshape(R, q_ref.shape[3])
    k = k_ref[0].astype(jnp.float32)                   # [psz, hd]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # [R, psz]
    # row r = b*TG + t*G + g is query token t of lane b; only rows of
    # this item's lane may take this page, causally (kv <= base + t)
    row = jax.lax.broadcasted_iota(jnp.int32, (R, psz), 0)
    row_lane = row // TG
    row_t = (row % TG) // G
    kvpos = slot * psz + jax.lax.broadcasted_iota(jnp.int32, (R, psz), 1)
    valid = (row_lane == lane) & (page >= 0) & (kvpos <= lens_ref[lane] + row_t)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                # [R, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                             # [R, psz]
    corr = jnp.exp(m_prev - m_new)                     # [R, 1]
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # [R, hd]
    m_scr[...] = m_new

    @pl.when(j == n_items - 1)
    def _finish():
        # rows that never saw a valid key (ragged tail past a lane's
        # feed, or an idle slot) keep m == NEG_INF and must output zeros
        seen = m_scr[...] > NEG_INF * 0.5
        hd = o_ref.shape[3]
        out = jnp.where(seen, acc_scr[...] / jnp.maximum(l_scr[...], 1e-20),
                        0.0)
        o_ref[:, 0] = out.reshape(B, TG, hd).astype(o_ref.dtype)


def verify_attention(q, k_pages, v_pages, page_table, base_lens,
                     interpret: bool = False):
    """Page-grouped verify attention.

    q: [B, T, H, hd] — T = k+1 verify positions per lane; k/v_pages:
    [P, psz, KH, hd] (drafts' K/V already appended); table: [B, maxp];
    base_lens: int32[B] lengths before the verify window.  Bit-for-bit
    the same math as `verify_attention_ref` / `paged_attention_chunk`,
    only the page-visit order differs.
    """
    B, T, H, hd = q.shape
    P, psz, KH, _ = k_pages.shape
    maxp = page_table.shape[1]
    G = H // KH
    TG = T * G
    scale = 1.0 / (hd ** 0.5)

    pages, lanes, slots = build_verify_schedule(
        page_table.astype(jnp.int32), base_lens.astype(jnp.int32), T, psz)
    n_items = int(pages.shape[0])

    # [B, T, KH, G, hd] -> [B, KH, T*G, hd]: row r = t * G + g
    qg = q.reshape(B, T, KH, G, hd).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(B, KH, TG, hd)
    kp = k_pages.transpose(0, 2, 1, 3).reshape(P * KH, psz, hd)
    vp = v_pages.transpose(0, 2, 1, 3).reshape(P * KH, psz, hd)

    grid = (KH, n_items)

    def q_map(h, j, pages, lanes, slots):
        return (0, h, 0, 0)

    def kv_map(h, j, pages, lanes, slots):
        # consecutive items with the same page id produce the same block
        # index here — Pallas skips the re-DMA, which is the one-read-
        # per-hot-page property; dead items clamp to resident page 0
        return (jnp.maximum(pages[j], 0) * KH + h, 0, 0)

    def lens_map(h, j, pages, lanes, slots):
        return (0,)

    out = pl.pallas_call(
        functools.partial(_verify_kernel, psz=psz, scale=scale, G=G, TG=TG),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((B, 1, TG, hd), q_map),
                pl.BlockSpec((1, psz, hd), kv_map),
                pl.BlockSpec((1, psz, hd), kv_map),
                pl.BlockSpec((B,), lens_map),
            ],
            out_specs=pl.BlockSpec((B, 1, TG, hd), q_map),
            scratch_shapes=[
                pltpu.VMEM((B * TG, 1), jnp.float32),
                pltpu.VMEM((B * TG, 1), jnp.float32),
                pltpu.VMEM((B * TG, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KH, TG, hd), q.dtype),
        interpret=interpret,
    )(pages, lanes, slots, qg, kp, vp, base_lens.astype(jnp.int32))
    out = out.reshape(B, KH, T, G, hd).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, T, H, hd)
