"""Pure-jnp oracle for speculative verify attention.

Semantically verify attention *is* chunked paged attention: every lane
carries 1 committed token + k draft tokens at positions base..base+k,
each attending causally to the lane's resident pages.  The oracle states
that contract independently of the Pallas schedule (which reorders the
page visits to read shared pages once; see kernel.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def verify_attention_ref(q, k_pages, v_pages, page_table, base_lens):
    """q: [B, T, H, hd]; k/v_pages: [P, psz, KH, hd]; table: [B, maxp];
    base_lens: int32[B] sequence lengths BEFORE the verify window.

    Query token t of lane b sits at absolute position base_lens[b] + t
    (the drafts' K/V are already appended to the pages) and attends to
    kv positions <= base_lens[b] + t on resident pages.  Rows past a
    lane's live feed return zeros (all-masked softmax is guarded) so the
    engine can ragged-mask afterwards.
    """
    B, T, H, hd = q.shape
    P, psz, KH, _ = k_pages.shape
    maxp = page_table.shape[1]
    L = maxp * psz
    safe = jnp.maximum(page_table, 0)
    k = k_pages[safe].reshape(B, L, KH, hd)
    v = v_pages[safe].reshape(B, L, KH, hd)
    if KH != H:
        k = jnp.repeat(k, H // KH, axis=2)
        v = jnp.repeat(v, H // KH, axis=2)
    kvpos = jnp.arange(L)
    qpos = base_lens[:, None] + jnp.arange(T)[None, :]          # [B, T]
    resident = jnp.repeat(page_table >= 0, psz, axis=1)         # [B, L]
    valid = (kvpos[None, None, :] <= qpos[:, :, None]) & resident[:, None, :]
    s = jnp.einsum("bthd,bkhd->bhtk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(valid[:, None], axis=-1, keepdims=True), p, 0.0)
    o = jnp.einsum("bhtk,bkhd->bthd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
