"""Jit'd wrapper for the SSD scan."""

from __future__ import annotations

import functools

import jax

from .kernel import ssd_scan as _kernel
from .ref import ssd_scan_ref as _ref


@functools.partial(jax.jit, static_argnames=("force",))
def ssd_scan(x, dt, A, Bm, Cm, D, force: str = "auto"):
    if force == "kernel" or (force == "auto"
                             and jax.default_backend() == "tpu"):
        y, h = _kernel(x, dt, A, Bm, Cm, D)
        return y, h.transpose(0, 1, 3, 2)   # [B,H,P,N] convention
    if force == "interpret":
        y, h = _kernel(x, dt, A, Bm, Cm, D, interpret=True)
        return y, h.transpose(0, 1, 3, 2)
    return _ref(x, dt, A, Bm, Cm, D)
