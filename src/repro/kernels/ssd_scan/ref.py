"""Pure-jnp oracle for the SSD scan: direct sequential recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, Bm, Cm, D):
    """Sequential SSM recurrence (the definition SSD must match).

    x: [B, S, H, P]; dt: [B, S, H]; A: [H]; Bm, Cm: [B, S, N]; D: [H].
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t + D x_t
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        a = jnp.exp(dtt * A[None, :])                       # [B,H]
        upd = jnp.einsum("bh,bn,bhp->bhpn", dtt, Bt, xt)
        h = h * a[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Ct, h)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          Bm.swapaxes(0, 1).astype(jnp.float32),
          Cm.swapaxes(0, 1).astype(jnp.float32))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h_fin
