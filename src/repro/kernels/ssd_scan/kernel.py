"""Pallas TPU Mamba-2 SSD kernel (chunked state-space duality).

grid = (B, H, n_chunks), chunk axis innermost/sequential; the carried
state h [P, N] lives in VMEM scratch.  Within a chunk (Q timesteps):

  y_diag = ((C B^T) .* L .* dt_j) x        — MXU [Q,Q]x[Q,P]
  y_off  = (C h_prev^T) .* exp(cum_a)      — MXU [Q,N]x[N,P]
  h     <- exp(a_total) h + (dt .* decay_out .* B)^T x

Tiles: x [Q, P], B/C [Q, N], with Q = 128 (MXU-aligned) and P, N = 64/128
from the assigned configs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
            y_ref, hout_ref, h_scr, *, Q: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)        # [Q, 1]... stored [Q,1]
    A = a_ref[0]                                  # [1] scalar per head
    Bm = b_ref[0, 0].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0, 0].astype(jnp.float32)         # [Q, N]
    D = d_ref[0]                                  # [1]

    a = dt * A                                    # [Q,1] negative
    cum = jnp.cumsum(a, axis=0)                   # [Q,1]
    a_total = cum[Q - 1]                          # [1]

    # within-chunk lower-triangular decay matrix
    seg = cum - cum.T                             # [Q,Q] = cum_i - cum_j
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(iota_i >= iota_j, jnp.exp(seg), 0.0)

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    w = cb * L * dt.T                             # [Q,Q] (dt_j along cols)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q,P]

    # carried-state contribution
    h = h_scr[...]                                # [N, P]
    y += jax.lax.dot_general(Cm, h, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) * jnp.exp(cum)

    # state update: h_new = exp(a_total) h + sum_j w_j B_j x_j^T
    decay_out = jnp.exp(a_total - cum)            # [Q,1]
    bw = Bm * (decay_out * dt)                    # [Q,N]
    upd = jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [N,P]
    h_scr[...] = h * jnp.exp(a_total) + upd

    y_ref[0, 0] = (y + x * D).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0, 0] = h_scr[...].astype(hout_ref.dtype)


def ssd_scan(x, dt, A, Bm, Cm, D, chunk: int = 128,
             interpret: bool = False):
    """x: [B, S, H, P]; dt: [B, S, H]; A, D: [H]; Bm, Cm: [B, S, N].

    Returns (y [B, S, H, P], h_final [B, H, N, P]).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    xt = x.transpose(0, 2, 1, 3)                  # [B, H, S, P]
    dtt = dt.transpose(0, 2, 1)[..., None]        # [B, H, S, 1]
    bt = Bm[:, None].repeat(1, axis=1)            # [B, 1, S, N]
    ct = Cm[:, None]

    grid = (Bsz, H, nc)
    y, hout = pl.pallas_call(
        functools.partial(_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, 0, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, 0, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), bt, ct, D.astype(jnp.float32))
    return y.transpose(0, 2, 1, 3), hout
