"""Pallas TPU RG-LRU recurrence kernel.

The gate matmuls run on the MXU outside (they are plain GEMMs); this
kernel computes the elementwise first-order recurrence
``h_t = a_t * h_{t-1} + b_t`` which has no matmul content — a VPU
kernel.  grid = (B, d_tiles, n_chunks) with the chunk axis sequential;
the carry h [1, d_tile] sits in VMEM scratch.  Within a chunk the
recurrence is evaluated by a log2(Q)-step Blelloch-style doubling on the
[Q, d_tile] tile (vector ops only), rather than a Q-step scalar loop:

  (a, b) o (a', b') = (a a', a' b + b')

d_tile = 256 lanes x f32; Q = 128 rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h_ref, hout_ref, h_scr, *, Q: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h_ref[0]

    a = a_ref[0].astype(jnp.float32)          # [Q, dt]
    b = b_ref[0].astype(jnp.float32)

    # inclusive scan of (a, b) pairs along axis 0 by doubling
    k = 1
    while k < Q:
        a_sh = jnp.pad(a, ((k, 0), (0, 0)))[:Q]          # a shifted by k
        b_sh = jnp.pad(b, ((k, 0), (0, 0)))[:Q]
        mask = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0) >= k
        b = jnp.where(mask, a * b_sh + b, b)
        a = jnp.where(mask, a * a_sh, a)
        k *= 2

    h0 = h_scr[...]                            # [1, dt]
    h = a * h0 + b                             # [Q, dt] all prefixes applied
    h_scr[...] = h[Q - 1:Q]
    hout_ref[0] = h.astype(hout_ref.dtype)


def rg_lru(a, b, h0=None, chunk: int = 128, d_tile: int = 256,
           interpret: bool = False):
    """a, b: [B, S, d] -> (h [B, S, d], h_final [B, d])."""
    B, S, d = a.shape
    Q = min(chunk, S)
    assert S % Q == 0 and d % d_tile == 0 or d <= d_tile
    if d < d_tile:
        d_tile = d
    nc = S // Q
    nd = d // d_tile
    if h0 is None:
        h0 = jnp.zeros((B, d), jnp.float32)

    grid = (B, nd, nc)
    hs = pl.pallas_call(
        functools.partial(_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, d_tile), lambda bb, dd, cc: (bb, cc, dd)),
            pl.BlockSpec((1, Q, d_tile), lambda bb, dd, cc: (bb, cc, dd)),
            pl.BlockSpec((1, 1, d_tile), lambda bb, dd, cc: (bb, 0, dd)),
        ],
        out_specs=pl.BlockSpec((1, Q, d_tile), lambda bb, dd, cc: (bb, cc, dd)),
        scratch_shapes=[pltpu.VMEM((1, d_tile), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((B, S, d), jnp.float32),
        interpret=interpret,
    )(a, b, h0[:, None, :])
    return hs, hs[:, -1]
