"""Jit'd wrapper for the RG-LRU recurrence."""

from __future__ import annotations

import functools

import jax

from .kernel import rg_lru as _kernel
from .ref import rg_lru_ref as _ref


@functools.partial(jax.jit, static_argnames=("force",))
def rg_lru(a, b, h0=None, force: str = "auto"):
    if force == "kernel" or (force == "auto"
                             and jax.default_backend() == "tpu"):
        return _kernel(a, b, h0)
    if force == "interpret":
        return _kernel(a, b, h0, interpret=True)
    return _ref(a, b, h0)
