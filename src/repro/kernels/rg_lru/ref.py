"""Pure-jnp oracle for the RG-LRU linear recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rg_lru_ref(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t, sequential.  a, b: [B, S, d]."""
    B, S, d = a.shape

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    h_init = h0 if h0 is not None else jnp.zeros((B, d), jnp.float32)
    h_fin, hs = jax.lax.scan(
        step, h_init.astype(jnp.float32),
        (a.swapaxes(0, 1).astype(jnp.float32),
         b.swapaxes(0, 1).astype(jnp.float32)))
    return hs.swapaxes(0, 1), h_fin
