"""Elastic scaling: re-mesh on device-membership change.

On a real cluster, membership changes arrive from the coordinator; the
policy below recomputes the nearest valid mesh, and the trainer restores
from the last checkpoint with the new shardings (parameters are saved
host-independent, so resharding is a restore-time layout decision).

The policy is pure and unit-tested: given a surviving device count it
keeps the model axis if possible (TP degree is architecture-critical)
and shrinks the data axis; batch is kept constant by raising gradient
accumulation so optimization dynamics are unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Tuple


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    grad_accum: int            # microbatches to keep the global batch
    note: str


def plan_for(n_devices: int, *, model_parallel: int = 16,
             full_data_parallel: int = 16,
             pods: int = 1) -> ElasticPlan:
    """Nearest valid (data, model) factorization for surviving devices."""
    mp = model_parallel
    while mp > 1 and n_devices % mp:
        mp //= 2
    data = n_devices // mp
    full_dp = full_data_parallel * pods
    # keep global batch: accumulate if we lost data-parallel ways
    accum = max(1, -(-full_dp // max(data, 1)))
    note = ("full mesh" if mp == model_parallel and data == full_dp
            else f"degraded: model {model_parallel}->{mp}, data {full_dp}->{data}")
    if pods > 1 and data % pods == 0 and mp == model_parallel:
        return ElasticPlan((pods, data // pods, mp), ("pod", "data", "model"),
                           accum, note)
    return ElasticPlan((data, mp), ("data", "model"), accum, note)


@dataclasses.dataclass(frozen=True)
class ServingPlan:
    surviving: Tuple[int, ...]   # shard ids still serving
    page_budget: int             # per-shard admission budget (physical)
    capacity_pages: int          # total admission capacity across survivors
    shed_pages: int              # backlog pages beyond capacity to shed
    note: str


def plan_serving_for(n_shards: int, dead: Iterable[int], page_budget: int,
                     backlog_pages: int = 0) -> ServingPlan:
    """Serving-plane analogue of :func:`plan_for` for shard loss.

    The per-shard page budget is physical (each DP shard owns its own
    pool), so losing a shard cannot be absorbed by raising the others'
    budgets — total admission capacity simply shrinks with the
    surviving shard count.  Any worst-case queued backlog beyond that
    capacity must be shed; picking *which* requests to drop (lowest SLO
    class, queue tail first) is the caller's policy
    (serving/sched.py)."""
    dead = set(dead)
    surviving = tuple(s for s in range(n_shards) if s not in dead)
    capacity = len(surviving) * page_budget
    shed = max(0, int(backlog_pages) - capacity)
    note = ("full mesh" if not dead else
            f"degraded: {n_shards}->{len(surviving)} shards"
            + (f", shed {shed} backlog pages" if shed else ""))
    return ServingPlan(surviving, page_budget, capacity, shed, note)
