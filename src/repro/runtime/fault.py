"""Fault tolerance: checkpoint/restart loop, failure injection,
straggler mitigation.

Production posture (1000+ nodes): failures are the steady state.  The
runtime treats the train step as a pure function of (state, batch), so
recovery is always "restore last complete checkpoint, rewind the data
stream to that step, continue" — correct because the data pipeline is a
pure function of the step index (see data/pipeline.py).

Components:
  * :class:`StepWatchdog` — median-based straggler detection plus an
    optional hard per-step timeout; shared by the training loop below
    and the serving engine (serving/engine.py), so both planes classify
    slow steps with one implementation.
  * :class:`FaultTolerantLoop` — wraps a step function with periodic
    (async) checkpointing, failure capture, bounded restart-with-backoff,
    and the step-time watchdog for stragglers.
  * :class:`FailureInjector` — deterministic fault schedule for tests
    (raise at step k / slow a step by t).  The serving plane's richer
    phase-boundary injector lives in serving/chaos.py.
On a real cluster the same loop runs per host with jax.distributed;
coordinator failures surface as exceptions here too.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

from ..checkpoint.ckpt import Checkpointer


class FailureInjector:
    def __init__(self, fail_at: Optional[Dict[int, Exception]] = None,
                 slow_at: Optional[Dict[int, float]] = None):
        self.fail_at = dict(fail_at or {})
        self.slow_at = dict(slow_at or {})

    def before_step(self, step: int) -> None:
        if step in self.slow_at:
            time.sleep(self.slow_at.pop(step))
        if step in self.fail_at:
            raise self.fail_at.pop(step)


@dataclasses.dataclass
class LoopStats:
    restarts: int = 0
    straggler_steps: int = 0
    completed_steps: int = 0
    step_times: List[float] = dataclasses.field(default_factory=list)


class StepWatchdog:
    """Step-time anomaly classifier: stragglers and hard timeouts.

    ``observe(step, dt)`` returns ``None`` for a normal step,
    ``"straggler"`` when ``dt`` exceeds ``straggler_factor`` times the
    rolling median of the last ``window`` steps (needing at least
    ``min_samples`` observations — cold-start compilations must not
    count), or ``"timeout"`` when ``dt`` exceeds the absolute
    ``timeout_s`` budget (0 disables).  A timeout outranks a straggler:
    it is the caller's signal to fail the step, not merely to note it.
    """

    def __init__(self, straggler_factor: float = 3.0, timeout_s: float = 0.0,
                 window: int = 64, min_samples: int = 8,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.straggler_factor = straggler_factor
        self.timeout_s = timeout_s
        self.window = window
        self.min_samples = min_samples
        self.on_straggler = on_straggler
        self.step_times: List[float] = []
        self.straggler_steps = 0
        self.timeout_steps = 0

    def observe(self, step: int, dt: float) -> Optional[str]:
        times = self.step_times
        times.append(dt)
        verdict = None
        if len(times) >= self.min_samples:
            tail = times[-self.window:]
            med = sorted(tail)[len(tail) // 2]
            if dt > self.straggler_factor * med:
                self.straggler_steps += 1
                if self.on_straggler:
                    self.on_straggler(step, dt)
                verdict = "straggler"
        if self.timeout_s > 0 and dt > self.timeout_s:
            self.timeout_steps += 1
            verdict = "timeout"
        if len(times) > 4 * self.window:
            del times[:2 * self.window]
        return verdict


class FaultTolerantLoop:
    def __init__(
        self,
        step_fn: Callable[[Any, Any], Any],      # (state, batch) -> state
        batch_fn: Callable[[int], Any],          # step -> batch
        ckpt: Checkpointer,
        save_every: int = 50,
        max_restarts: int = 5,
        straggler_factor: float = 3.0,
        injector: Optional[FailureInjector] = None,
        on_straggler: Optional[Callable[[int, float], None]] = None,
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.straggler_factor = straggler_factor
        self.injector = injector
        self.on_straggler = on_straggler
        self.stats = LoopStats()
        self.watchdog = StepWatchdog(straggler_factor=straggler_factor,
                                     on_straggler=on_straggler)
        # LoopStats.step_times aliases the watchdog's rolling buffer so
        # existing consumers keep reading the same list object
        self.stats.step_times = self.watchdog.step_times

    def run(self, state: Any, n_steps: int) -> Any:
        start = self.ckpt.latest_step()
        step = 0
        if start is not None:
            state = self.ckpt.restore(start, state)
            step = start + 1
        restarts = 0
        while step < n_steps:
            try:
                t0 = time.time()
                if self.injector:
                    self.injector.before_step(step)
                batch = self.batch_fn(step)
                state = self.step_fn(state, batch)
                dt = time.time() - t0
                self._watchdog(step, dt)
                self.stats.completed_steps += 1
                if step % self.save_every == 0 or step == n_steps - 1:
                    self.ckpt.save(step, state, async_=True)
                step += 1
            except Exception:
                restarts += 1
                self.stats.restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                last = self.ckpt.latest_step()
                if last is not None:
                    state = self.ckpt.restore(last, state)
                    step = last + 1
                else:
                    step = 0
        self.ckpt.wait()
        return state

    def _watchdog(self, step: int, dt: float) -> None:
        if self.watchdog.observe(step, dt) == "straggler":
            self.stats.straggler_steps += 1
