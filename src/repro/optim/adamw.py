"""AdamW with cosine schedule, global-norm clipping, bf16-param support.

Optimizer state keeps fp32 master copies of bf16 params (mixed-precision
training discipline); moments are fp32.  Pure-functional, pjit-friendly:
state is a pytree matching the param tree.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    master: Any              # fp32 master params (None leaves if fp32 already)


class AdamWConfig(NamedTuple):
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 200
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr_peak * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Any) -> AdamWState:
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # copy=True: fp32 params must not alias the master buffer (donation)
    master = jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros(), zeros(), master)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, state: AdamWState, grads: Any,
          params: Any) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, state.step)
    b1, b2 = cfg.b1, cfg.b2
    t = state.step + 1
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(g, m, v, master, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                    + cfg.weight_decay * master)
        return new_master.astype(p.dtype), m, v, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_ma = treedef.flatten_up_to(state.master)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(*args) for args in zip(flat_g, flat_m, flat_v, flat_ma, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_ma = treedef.unflatten([o[3] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(t, new_m, new_v, new_ma), metrics
