from .adamw import AdamWConfig, AdamWState, init, apply, schedule, global_norm
