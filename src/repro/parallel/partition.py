"""Logical-axis -> mesh-axis sharding rules.

Every parameter/state array declares logical axis names (see
``repro.models.layers.ParamDef``); this module maps them onto the mesh:

  vocab / heads / kv_heads / mlp / experts -> "model"   (TP / EP)
  batch                                    -> ("pod", "data") or "data"
  embed / head_dim / layers / state dims   -> replicated

A dimension is only sharded if divisible by the mesh axis size (GSPMD
could pad, but padded shards waste memory and skew the roofline; tiny
archs like whisper fall back to pure DP, which is the right call).

Alternate rule sets are first-class for the §Perf hillclimb:
  "tp"        — the default above (tensor parallel weights)
  "fsdp"      — additionally shard the embed axis over "data"
                (ZeRO-3-style fully sharded params)
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def is_def(x) -> bool:
    """Duck-typed ParamDef check (avoids a models<->parallel import cycle)."""
    return hasattr(x, "axes") and hasattr(x, "shape") and hasattr(x, "init")

RULE_SETS: Dict[str, Dict[str, Any]] = {
    "tp": {
        "vocab": "model", "heads": "model", "kv_heads": "model",
        "mlp": "model", "experts": "model",
        "embed": None, "head_dim": None, "layers": None,
    },
    "fsdp": {
        "vocab": "model", "heads": "model", "kv_heads": "model",
        "mlp": "model", "experts": "model",
        "embed": "data", "head_dim": None, "layers": None,
    },
}
RULE_SETS["sp"] = RULE_SETS["fsdp"]   # + seq-sharded activations (launcher)
# Serving for huge MoE: expert weights sharded over the data axis too
# (ZeRO-style for inference; tokens are tiny, weights are not — GSPMD
# routes tokens via all-to-all instead of replicating 790GB of experts).
RULE_SETS["ep_serve"] = {
    "vocab": "model", "heads": "model", "kv_heads": "model",
    "mlp": "model", "experts": "data", "embed": None,
    "head_dim": None, "layers": None,
}


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def spec_for(defn, rules: Dict[str, Any], mesh: Mesh) -> P:
    parts = []
    used = set()
    for dim, ax in zip(defn.shape, defn.axes):
        mesh_ax = rules.get(ax) if ax else None
        if (mesh_ax is None or mesh_ax in used
                or dim % _axis_size(mesh, mesh_ax) != 0):
            parts.append(None)
        else:
            parts.append(mesh_ax)
            used.add(mesh_ax)
    return P(*parts)


def param_specs(defs: Any, mesh: Mesh, rules: str = "tp") -> Any:
    rr = RULE_SETS[rules]
    return jax.tree.map(lambda d: spec_for(d, rr, mesh), defs, is_leaf=is_def)


def param_shardings(defs: Any, mesh: Mesh, rules: str = "tp") -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(defs, mesh, rules))


def batch_axes(mesh: Mesh):
    """Mesh axes carrying the batch: ("pod","data") multi-pod, else "data"."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def data_spec(mesh: Mesh, ndim: int, batch_dim: int = 0) -> P:
    parts = [None] * ndim
    ba = batch_axes(mesh)
    parts[batch_dim] = ba if len(ba) > 1 else ba[0]
    return P(*parts)


def dp_size(mesh: Mesh) -> int:
    return _axis_size(mesh, batch_axes(mesh))


# ------------------------------------------------- activation constraints
#
# GSPMD occasionally resolves mixed weight/activation shardings with
# full-batch activation all-reduces (observed on the whisper fsdp cell:
# an f32[256,4096,6,64] all-reduce instead of a 24KB weight all-gather).
# Explicit batch-dim constraints on the residual stream pin the layout.
# The active mesh is registered by the launcher before tracing; when no
# mesh is registered (CPU unit tests) constraints are no-ops.

_ACTIVE_MESH: Optional[Mesh] = None
_SEQ_SHARD: bool = False


def set_activation_mesh(mesh: Optional[Mesh], seq_shard: bool = False) -> None:
    """Register the mesh for activation constraints.

    seq_shard=True additionally shards the sequence dim of [B, S, d]
    residual activations over the "model" axis (Megatron-style sequence
    parallelism): per-token ops (norms, residual adds, projections' token
    dim) run on S/TP tokens per device; GSPMD inserts the all-to-all /
    all-gather resharding around attention and MoE sorts.  §Perf A3.
    """
    global _ACTIVE_MESH, _SEQ_SHARD
    _ACTIVE_MESH = mesh
    _SEQ_SHARD = seq_shard


def constrain_batch(x, batch_dim: int = 0):
    """Constrain x's batch dim to the data axes; no-op without a mesh."""
    if _ACTIVE_MESH is None:
        return x
    mesh = _ACTIVE_MESH
    ba = batch_axes(mesh)
    ba = ba if len(ba) > 1 else ba[0]
    if x.shape[batch_dim] % _axis_size(mesh, ba) != 0:
        return x
    parts: list = [None] * x.ndim
    parts[batch_dim] = ba
    if (_SEQ_SHARD and x.ndim == 3 and batch_dim == 0
            and x.shape[1] % _axis_size(mesh, "model") == 0):
        parts[1] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
