"""Gradient compression: int8 quantization with error feedback.

Distributed-optimization trick for bandwidth-bound training: gradients
are quantized to int8 with per-tensor scales before the data-parallel
all-reduce; the quantization error is carried in an error-feedback
buffer and added to the next step's gradients (Seide et al. '14, 1-bit
SGD lineage; here 8-bit symmetric).  Cuts DP collective bytes 4x vs
fp32 / 2x vs bf16 at negligible quality cost for these scales.

Usage: wrap the per-microbatch gradient before ``psum``/pmean, or let
GSPMD's all-reduce operate on the int8 tensors by quantizing inside the
jitted step (the dry-run hillclimb measures the collective-term delta).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any        # same tree as grads, fp32


def init_error_feedback(grads_like: Any) -> EFState:
    return EFState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, ef: EFState) -> Tuple[Any, Any, EFState]:
    """Returns (q_tree, scale_tree, new_ef).  g' = g + residual; the
    dequantization error goes back into the residual."""
    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, s = quantize(g)
        err = g - dequantize(q, s)
        return q, s, err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    qs = treedef.unflatten([o[0] for o in out])
    ss = treedef.unflatten([o[1] for o in out])
    ef = EFState(treedef.unflatten([o[2] for o in out]))
    return qs, ss, ef


def decompress_tree(qs: Any, ss: Any) -> Any:
    return jax.tree.map(dequantize, qs, ss)


def compressed_grads(grads: Any, ef: EFState) -> Tuple[Any, EFState]:
    """Round-trip compress (models the all-reduce payload); returns the
    dequantized gradients the optimizer sees plus the new EF state."""
    qs, ss, ef = compress_tree(grads, ef)
    return decompress_tree(qs, ss), ef
